"""Admission control: token-bucket budgets, bounded queues, graceful
degradation, deadline propagation.

The serving loop so far ran *open-loop*: every arrival was executed at
full plan depth no matter the backlog, so overload turned into unbounded
queueing delay — the exact failure mode the paper's throughput headline
is supposed to prevent at scale.  The ``AdmissionController`` puts a
shed ladder in front of the Searcher (DESIGN.md §12):

    admit     budget available at full cost — run the primary plan
    degrade   budget only covers a *discounted* cost, or the backlog has
              crossed the degrade watermark, or the request's deadline no
              longer fits the observed latency — run the **degraded
              plan** (shallower rerank depth, smaller nprobe/ef: recall
              bends, the process does not break)
    shed      queue at its hard bound, bucket empty even at the
              discounted cost, or deadline already blown — reject
              outright (the only polite answer left)

Costs are measured in *queries* (a 32-query batch spends 32 tokens): the
bucket meters work, not requests.  ``observe`` feeds an EMA of execute
latency back in, which is what deadline re-checks compare remaining
budget against.  Every decision increments shared counters that
telemetry serializes, and the clock is injectable so the ladder is
deterministic under test.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.knn.base import SearchParams

#: decision actions, in ladder order
ADMIT, DEGRADE, SHED = "admit", "degrade", "shed"


@dataclasses.dataclass(frozen=True)
class Decision:
    """One admission verdict: what to run (if anything) and why."""

    action: str                # admit | degrade | shed
    reason: str                # ok | queue | budget | deadline
    tokens: float = 0.0        # tokens actually charged

    @property
    def admitted(self) -> bool:
        return self.action != SHED

    @property
    def degraded(self) -> bool:
        return self.action == DEGRADE


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s, ``burst`` capacity."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate/burst must be positive, got {rate}/{burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self.clock = clock
        self._tokens = float(burst)
        self._t = clock()

    def _refill(self) -> None:
        now = self.clock()
        self._tokens = min(self.burst, self._tokens + (now - self._t) * self.rate)
        self._t = now

    @property
    def available(self) -> float:
        self._refill()
        return self._tokens

    def take(self, tokens: float) -> bool:
        self._refill()
        if self._tokens >= tokens:
            self._tokens -= tokens
            return True
        return False


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """How a degraded request differs from a full one.

    The knobs mirror the recall/cost dials every plan already has: the
    rerank tail shrinks (or disappears), IVF probes fewer lists, the
    graph walk narrows.  ``degrade_cost`` is the token discount — the
    fraction of full cost a degraded request is charged, which is what
    makes degradation a real pressure valve rather than a rename.
    """

    rerank_scale: float = 0.25      # degraded depth = ceil(scale * full)
    nprobe_scale: float = 0.5
    ef_scale: float = 0.5
    degrade_cost: float = 0.25
    budget_scale: float = 0.5       # cascade stage budgets shrink by this

    def params(self, sp: SearchParams, k: Optional[int] = None) -> SearchParams:
        return dataclasses.replace(
            sp,
            nprobe=max(1, int(sp.nprobe * self.nprobe_scale)),
            ef_search=max(1, int(sp.ef_search * self.ef_scale)),
            budgets=self.budgets(sp.budgets, k),
        )

    def budgets(
        self, budgets: Optional[tuple[int, ...]], k: Optional[int]
    ) -> Optional[tuple[int, ...]]:
        """Degraded cascade stage budgets: every fetch depth shrinks by
        ``budget_scale`` but no stage drops below ``k`` (or below 1 when
        k is unset) — the shrunken schedule must stay a valid
        non-increasing cascade, so the floor is applied uniformly and
        ceil-rounding preserves the ordering of the full schedule."""
        if budgets is None:
            return None
        floor = max(1, int(k or 1))
        return tuple(
            max(floor, int(-(-b * self.budget_scale // 1))) for b in budgets
        )

    def rerank_depth(self, depth: int, k: int) -> int:
        """Degraded rerank depth (never below k; 0 stays 0 = no tail)."""
        if depth <= 0:
            return 0
        return max(k, int(-(-depth * self.rerank_scale // 1)))


class AdmissionController:
    """The shed ladder in front of a serving session.

    rate_qps / burst   token budget (tokens = queries)
    max_queue          hard backlog bound — arrivals beyond it shed
    degrade_queue      soft watermark — arrivals beyond it degrade
                       (default: half the hard bound)
    policy             how much a degraded plan backs off / costs
    counters           any Counter-like mapping with ``+=`` semantics;
                       serve passes telemetry's registry so admission
                       numbers land in the session report for free
    """

    def __init__(
        self,
        *,
        rate_qps: float,
        burst: Optional[float] = None,
        max_queue: int = 64,
        degrade_queue: Optional[int] = None,
        policy: Optional[DegradePolicy] = None,
        clock: Callable[[], float] = time.monotonic,
        counters=None,
    ):
        if max_queue <= 0:
            raise ValueError(f"max_queue must be positive, got {max_queue}")
        self.bucket = TokenBucket(rate_qps, burst or rate_qps, clock)
        self.max_queue = int(max_queue)
        self.degrade_queue = (int(degrade_queue) if degrade_queue is not None
                              else max(1, self.max_queue // 2))
        self.policy = policy or DegradePolicy()
        self.clock = clock
        import collections

        self.counters = counters if counters is not None else collections.Counter()
        self._ema_latency = 0.0

    # -- latency feedback (deadline re-checks compare against this) --------
    def observe(self, latency_s: float, alpha: float = 0.25) -> None:
        if self._ema_latency == 0.0:
            self._ema_latency = float(latency_s)
        else:
            self._ema_latency += alpha * (float(latency_s) - self._ema_latency)

    @property
    def ema_latency(self) -> float:
        return self._ema_latency

    # -- the ladder --------------------------------------------------------
    def admit(self, n_queries: int, queue_depth: int,
              deadline: Optional[float] = None) -> Decision:
        """Arrival-time decision for an ``n_queries``-query request."""
        now = self.clock()
        if deadline is not None and now >= deadline:
            return self._count(Decision(SHED, "deadline"), n_queries)
        if queue_depth >= self.max_queue:
            return self._count(Decision(SHED, "queue"), n_queries)
        cost = float(n_queries)
        degraded_cost = cost * self.policy.degrade_cost
        over_watermark = queue_depth >= self.degrade_queue
        if not over_watermark and self.bucket.take(cost):
            return self._count(Decision(ADMIT, "ok", cost), n_queries)
        if self.bucket.take(degraded_cost):
            reason = "queue" if over_watermark else "budget"
            return self._count(Decision(DEGRADE, reason, degraded_cost),
                               n_queries)
        return self._count(Decision(SHED, "budget"), n_queries)

    def recheck(self, decision: Decision,
                deadline: Optional[float] = None) -> Decision:
        """Dequeue-time deadline propagation: a request admitted at
        arrival may have aged in the queue.  Blown deadline -> shed;
        remaining budget below the observed latency -> degrade."""
        if decision.action == SHED or deadline is None:
            return decision
        now = self.clock()
        if now >= deadline:
            return self._count(Decision(SHED, "deadline"), 0, recheck=True)
        if (decision.action == ADMIT
                and self._ema_latency > 0.0
                and deadline - now < self._ema_latency):
            return self._count(Decision(DEGRADE, "deadline", decision.tokens),
                               0, recheck=True)
        return decision

    def _count(self, d: Decision, n_queries: int, recheck: bool = False) -> Decision:
        self.counters[f"admission_{d.action}"] += 1
        self.counters[f"admission_{d.action}_{d.reason}"] += 1
        if recheck:
            self.counters["admission_rechecks"] += 1
        if d.action == SHED and n_queries:
            self.counters["admission_shed_queries"] += int(n_queries)
        return d
