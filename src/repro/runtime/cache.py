"""Hot-path cache tiers: exact-result cache and PQ LUT-block cache.

Serving traffic is zipfian — a small set of hot queries repeats — and the
Searcher recomputes every repeat from scratch.  Two tiers fix that
(DESIGN.md §12):

  * **result tier** (``CachedSearcher`` over a ``TTLLRUCache``): the
    whole ``SearchResult`` keyed on a fingerprint of (canonicalized
    query bytes, k, params, index version).  Keys are *semantic*: the
    query batch is normalized to contiguous fp32 before fingerprinting
    — exactly the form every compiled runner consumes — so a float64
    copy, an f32 view with exotic strides, and the original batch all
    hit one entry instead of three.  A hit is **bit-identical** to the
    uncached run: the searcher itself is handed the same canonical
    array that was fingerprinted, and the cache stores the materialized
    score/id arrays it produced, so parity is structural, not
    approximate.  The version component (serve wires the replan
    generation / manifest epoch in) invalidates across mutations
    without any scan of the cache.
  * **LUT tier** (``LUTCache`` installed via ``engine.set_lut_cache``):
    per-query ADC lookup tables keyed on (query fingerprint, codebook
    fingerprint, metric).  Repeated query batches skip the
    ``build_pq_lut`` einsum + Eq. 1 int8 quantization on the eager/
    one-shot path.  Inside a jitted Searcher bucket the LUT is fused
    into the compiled executable (queries are tracers there — the hook
    detects that and stands aside), so this tier serves exactly the
    paths the compiler cannot: eager search, one-shot sessions, and
    ad-hoc rescoring.

Both tiers share one eviction discipline: LRU bounded by ``capacity``
plus an optional TTL (stale results must age out even if hot), and both
surface ``hits/misses/evictions/expirations`` counters that serve.py
merges into session stats and telemetry.
"""

from __future__ import annotations

import collections
import dataclasses
import hashlib
import time
from typing import Any, Callable, Optional

import numpy as np

#: sentinel distinguishing "miss" from a cached None
MISS = object()


def fingerprint(*parts: Any) -> str:
    """Stable blake2b fingerprint of arrays / bytes / scalars / strings.

    Arrays hash over dtype + shape + raw bytes, so two batches fingerprint
    equal iff they are bit-identical — the invariant the result tier's
    bit-parity guarantee rests on.
    """
    h = hashlib.blake2b(digest_size=16)
    for p in parts:
        if hasattr(p, "shape"):            # ndarray / jax.Array
            a = np.asarray(p)
            h.update(str(a.dtype).encode())
            h.update(np.asarray(a.shape, np.int64).tobytes())
            h.update(np.ascontiguousarray(a).tobytes())
        elif isinstance(p, bytes):
            h.update(p)
        else:
            h.update(repr(p).encode())
        h.update(b"|")
    return h.hexdigest()


class TTLLRUCache:
    """LRU cache with optional TTL and full hit/miss/eviction accounting.

    ``clock`` is injectable (tests drive expiry deterministically).  Not
    thread-safe by design: each tier lives on the request path of one
    serving loop; the maintenance thread never touches caches.
    """

    def __init__(self, capacity: int, ttl_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        if capacity <= 0:
            raise ValueError(f"cache capacity must be positive, got {capacity}")
        if ttl_s is not None and ttl_s <= 0:
            raise ValueError(f"cache ttl must be positive, got {ttl_s}")
        self.capacity = int(capacity)
        self.ttl_s = ttl_s
        self.clock = clock
        self._d: collections.OrderedDict[Any, tuple[float, Any]] = (
            collections.OrderedDict()
        )
        self.counters = collections.Counter(
            hits=0, misses=0, evictions=0, expirations=0
        )

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key):
        """Cached value or the ``MISS`` sentinel (counts either way)."""
        entry = self._d.get(key)
        if entry is not None:
            t, value = entry
            if self.ttl_s is not None and self.clock() - t > self.ttl_s:
                del self._d[key]
                self.counters["expirations"] += 1
            else:
                self._d.move_to_end(key)
                self.counters["hits"] += 1
                return value
        self.counters["misses"] += 1
        return MISS

    def put(self, key, value) -> None:
        if key in self._d:
            del self._d[key]
        self._d[key] = (self.clock(), value)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.counters["evictions"] += 1

    def get_or_build(self, key, builder: Callable[[], Any]):
        v = self.get(key)
        if v is MISS:
            v = builder()
            self.put(key, v)
        return v

    def clear(self) -> None:
        self._d.clear()

    def stats(self) -> dict:
        return {"entries": len(self._d), "capacity": self.capacity,
                "ttl_s": self.ttl_s, **self.counters}


class LUTCache(TTLLRUCache):
    """The PQ LUT-block tier — install with ``engine.set_lut_cache``.

    Keys combine the query-batch fingerprint with a fingerprint of the
    store's codebooks (not ``id(store)``: object ids can be recycled,
    array bytes cannot lie), so a cache shared across indexes can never
    serve one index's tables to another.
    """

    def key_for(self, queries, codebooks, metric: str, lpq: bool):
        return ("lut", fingerprint(queries), fingerprint(codebooks),
                metric, bool(lpq))


@dataclasses.dataclass
class _CachedEntry:
    scores: np.ndarray
    ids: np.ndarray
    stats: dict


class CachedSearcher:
    """The result tier: a drop-in wrapper over a planned ``Searcher``.

    ``version`` feeds the cache key — serve passes a callable returning
    its replan generation (bumped on every re-plan, i.e. whenever the
    pinned snapshot changes), so entries from a superseded snapshot can
    never satisfy a fresh request.  Hits return the stored arrays
    verbatim (bit-identical to the miss that produced them) with
    ``stats["cache"] = "hit"`` and zeroed read accounting — a hit reads
    no corpus bytes, and the session totals should say so.
    """

    def __init__(self, searcher, cache: TTLLRUCache,
                 version: Callable[[], Any] = lambda: 0):
        self.searcher = searcher
        self.cache = cache
        self.version = version

    @property
    def rerank(self):
        return self.searcher.rerank

    @property
    def n_shards(self) -> int:
        return self.searcher.n_shards

    def buckets_for(self, q_len: int):
        return self.searcher.buckets_for(q_len)

    @staticmethod
    def canonicalize(queries) -> np.ndarray:
        """The semantic-key normal form: contiguous fp32.

        Every compiled runner starts with ``jnp.asarray(q, float32)``,
        so any two batches that agree after this cast are the *same
        search* — dtype (f64 copies), memory layout (strided views) and
        array flavor (jax vs numpy) must not fragment the key space.
        Fingerprinting the canonical array and then searching that same
        array is what keeps hits bit-identical to misses.
        """
        return np.ascontiguousarray(np.asarray(queries, dtype=np.float32))

    def _key(self, q: np.ndarray):
        s = self.searcher
        return ("result", fingerprint(q), s.k, s.params, self.version())

    def __call__(self, queries):
        q = self.canonicalize(queries)
        key = self._key(q)
        entry = self.cache.get(key)
        if entry is not MISS:
            stats = dict(entry.stats)
            stats.update(cache="hit", bytes_read=0, chunks=0, rerank_bytes=0)
            from repro.knn import base as B

            return B.SearchResult(entry.scores, entry.ids, stats)
        res = self.searcher(q)
        scores = np.asarray(res.scores)
        ids = np.asarray(res.ids)
        self.cache.put(key, _CachedEntry(scores, ids, dict(res.stats)))
        res.stats["cache"] = "miss"
        return res
