"""Named runtime profiles: one reproducible environment per serve/bench run.

Every serve report and ``BENCH_*.json`` so far recorded *ad-hoc* backend
state — whatever platform/XLA flags the process happened to inherit.  A
``RuntimeProfile`` makes that state a named, versioned artifact (in the
spirit of bayespec's ``elisa/util/config.py`` environment helpers):
platform/backend selection, an XLA flag set, host-core pinning
(``--xla_force_host_platform_device_count``), the NaN-debug toggle, x64,
and the deterministic-seed policy are resolved **once at process start**
(``resolve`` + ``apply``) and stamped into every report (``stamp``), so
CPU-interpret numbers can never be mistaken for hardware numbers and two
runs of the same profile are comparable by construction.

    from repro.runtime import profile as rt
    rt.apply(rt.resolve("ci-cpu"))      # before the first jax op
    meta["runtime"] = rt.stamp()        # in every BENCH_*.json / report

Selection order: explicit name > ``REPRO_RUNTIME_PROFILE`` env var >
``"default"``.  ``apply`` must run before JAX initializes its backend —
platform/host-device-count/XLA flags are start-of-process knobs (the
same contract as bayespec's ``set_platform``/``set_cpu_cores``).
"""

from __future__ import annotations

import dataclasses
import os
import platform as _platform
import warnings
from typing import Optional

ENV_VAR = "REPRO_RUNTIME_PROFILE"

_PROFILE_FIELDS = ("name", "platform", "host_device_count", "xla_flags",
                   "nan_debug", "x64", "seed", "deterministic")


@dataclasses.dataclass(frozen=True)
class RuntimeProfile:
    """One named runtime environment, resolved at process start.

    name               registry key, stamped into every artifact
    platform           forced jax platform ("cpu"/"gpu"/"tpu"); None =
                       let jax pick (the honest-autodetect default)
    host_device_count  pin this many host CPU devices
                       (``--xla_force_host_platform_device_count`` — the
                       sharded-serving / core-pinning knob); None = leave
    xla_flags          extra XLA_FLAGS tokens appended to the environment
    nan_debug          ``jax_debug_nans`` (fail fast on NaN scores)
    x64                ``jax_enable_x64``
    seed               the deterministic-seed policy: the base PRNG seed
                       every profiled entry point derives its keys from
    deterministic      False marks a profile whose runs are *expected* to
                       differ (e.g. time-seeded soak runs) — stamped so
                       the trend gate can refuse to compare them
    """

    name: str
    platform: Optional[str] = None
    host_device_count: Optional[int] = None
    xla_flags: tuple[str, ...] = ()
    nan_debug: bool = False
    x64: bool = False
    seed: int = 0
    deterministic: bool = True

    # -- (de)serialization round-trip --------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["xla_flags"] = list(self.xla_flags)
        return d

    @staticmethod
    def from_dict(d: dict) -> "RuntimeProfile":
        unknown = set(d) - set(_PROFILE_FIELDS)
        if unknown:
            raise ValueError(f"unknown RuntimeProfile fields: {sorted(unknown)}")
        d = dict(d)
        d["xla_flags"] = tuple(d.get("xla_flags") or ())
        return RuntimeProfile(**d)


#: the named registry — every entry point resolves one of these (or a
#: user-registered one) so serving/bench environments are enumerable
PROFILES: dict[str, RuntimeProfile] = {
    # honest autodetect: no forcing, deterministic seed 0
    "default": RuntimeProfile(name="default"),
    # single-process CPU dev box: pin platform so a stray GPU/TPU plugin
    # cannot silently change the numbers a debug session reproduces
    "cpu-dev": RuntimeProfile(name="cpu-dev", platform="cpu"),
    # CI profile: CPU, one pinned host device, NaN debugging off, fixed
    # seed — the environment every BENCH_*.json trend point shares
    "ci-cpu": RuntimeProfile(name="ci-cpu", platform="cpu",
                             host_device_count=1),
    # sharded-serving rehearsal on one host: 4 pinned host devices so
    # mesh plans (serve --shards) exercise the real collective paths
    "cpu-mesh4": RuntimeProfile(name="cpu-mesh4", platform="cpu",
                                host_device_count=4),
    # debugging: fail fast on NaN scores (Eq. 1 constant bugs surface as
    # NaN after division by zero-σ dims)
    "debug-nan": RuntimeProfile(name="debug-nan", platform="cpu",
                                nan_debug=True),
    # TPU serving: leave the platform to autodetect-with-tpu-preference
    # and enable the latency-hiding scheduler class of flags
    "tpu-serve": RuntimeProfile(
        name="tpu-serve", platform="tpu",
        xla_flags=("--xla_tpu_enable_latency_hiding_scheduler=true",),
    ),
}

#: the profile ``apply`` actually installed in this process (at most one)
_ACTIVE: Optional[RuntimeProfile] = None


def register(profile: RuntimeProfile) -> RuntimeProfile:
    """Add/replace a named profile (config files can extend the registry)."""
    PROFILES[profile.name] = profile
    return profile


def from_file(path) -> RuntimeProfile:
    """Load a profile from a JSON file and register it.

    The file holds one ``RuntimeProfile.to_dict()`` object (see
    ``to_file`` for the writer); unknown fields are rejected with the
    field list, so a typo'd knob cannot silently fall back to a default.
    This is the ``serve --profile-file`` path: ops can ship environment
    definitions as reviewed artifacts instead of editing code.
    """
    import json

    with open(path) as f:
        d = json.load(f)
    if not isinstance(d, dict):
        raise ValueError(
            f"profile file {path!r} must hold one JSON object "
            f"(RuntimeProfile.to_dict()), got {type(d).__name__}"
        )
    if "name" not in d:
        raise ValueError(
            f"profile file {path!r} needs a 'name' field — profiles are "
            "named artifacts stamped into every report"
        )
    return register(RuntimeProfile.from_dict(d))


def to_file(profile: RuntimeProfile, path) -> None:
    """Write ``profile`` as JSON — ``from_file``'s exact inverse."""
    import json

    with open(path, "w") as f:
        json.dump(profile.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")


def resolve(name: Optional[str] = None) -> RuntimeProfile:
    """Resolve a profile: explicit name > $REPRO_RUNTIME_PROFILE > default."""
    name = name or os.environ.get(ENV_VAR) or "default"
    try:
        return PROFILES[name]
    except KeyError:
        raise ValueError(
            f"unknown runtime profile {name!r}; registered: "
            f"{sorted(PROFILES)}"
        ) from None


def apply(profile: RuntimeProfile) -> RuntimeProfile:
    """Install ``profile`` into this process (idempotent per profile).

    Must run before the first jax operation: platform selection, host
    device count and XLA flags only take effect at backend init.  A
    second ``apply`` of the *same* profile is a no-op; a different one
    warns and is ignored (the backend is already up — restart to switch).
    """
    global _ACTIVE
    import jax

    if _ACTIVE is not None:
        if profile.name != _ACTIVE.name:
            warnings.warn(
                f"runtime profile {_ACTIVE.name!r} already applied; ignoring "
                f"{profile.name!r} (profiles are process-start state)",
                RuntimeWarning, stacklevel=2,
            )
        return _ACTIVE

    tokens = list(profile.xla_flags)
    if profile.host_device_count is not None:
        tokens.append("--xla_force_host_platform_device_count="
                      f"{int(profile.host_device_count)}")
    if tokens:
        existing = os.environ.get("XLA_FLAGS", "")
        fresh = [t for t in tokens if t not in existing.split()]
        if fresh:
            os.environ["XLA_FLAGS"] = (existing + " " + " ".join(fresh)).strip()
    if profile.platform is not None:
        jax.config.update("jax_platform_name", profile.platform)
    jax.config.update("jax_debug_nans", bool(profile.nan_debug))
    jax.config.update("jax_enable_x64", bool(profile.x64))
    _ACTIVE = profile
    return profile


def active() -> RuntimeProfile:
    """The applied profile, or the resolved-but-unapplied default — so
    ``stamp`` always has a name to report."""
    return _ACTIVE if _ACTIVE is not None else resolve()


def key(profile: Optional[RuntimeProfile] = None):
    """The profile's deterministic base PRNG key (seed policy in one place)."""
    import jax

    return jax.random.PRNGKey((profile or active()).seed)


def stamp(profile: Optional[RuntimeProfile] = None) -> dict:
    """The runtime-metadata block every report/BENCH_*.json embeds.

    Resolved *facts* (backend, device kind, device count, interpret-mode
    flag) alongside the profile that asked for them — ``interpret`` is
    the "honest perf story" bit: True means every Pallas number in the
    artifact ran in CPU interpret mode and is a parity signal, not a
    hardware perf signal.
    """
    import jax

    p = profile or active()
    backend = jax.default_backend()
    dev = jax.devices()[0]
    return {
        "profile": p.name,
        # the installed TuneTable's dispatch hash (None = fallback
        # constants) — trend.py keys comparability on it, so two runs
        # with different tunings never get compared as one trajectory
        "tune_table": _tune_table_hash(),
        "applied": _ACTIVE is not None,
        "backend": backend,
        "device_kind": getattr(dev, "device_kind", str(dev)),
        "n_devices": len(jax.devices()),
        "interpret": backend != "tpu",
        "platform": _platform.platform(),
        "jax_version": jax.__version__,
        "seed": p.seed,
        "deterministic": p.deterministic,
        "nan_debug": p.nan_debug,
        "x64": p.x64,
        "xla_flags": list(p.xla_flags),
        "host_device_count": p.host_device_count,
    }


def _tune_table_hash() -> Optional[str]:
    """The active TuneTable's dispatch hash (lazy import — tune.table
    depends on this module for ``live_stamp``)."""
    from repro.tune import table as tunetable

    return tunetable.active_hash()


def _reset_for_tests() -> None:
    """Test hook: forget the applied profile (config flags stay as-is)."""
    global _ACTIVE
    _ACTIVE = None
