# The production runtime subsystem (DESIGN.md §12): named backend
# profiles resolved once at process start and stamped into every
# artifact (profile), hot-path result/LUT caching (cache), token-bucket
# admission control with a degrade/shed ladder and deadline propagation
# (admission), background compaction + drift recalibration off the
# request path (maintenance), and the structured per-request telemetry
# the serve report and the CI trend gate consume (telemetry).
from repro.runtime import profile
from repro.runtime.admission import (
    ADMIT,
    DEGRADE,
    SHED,
    AdmissionController,
    Decision,
    DegradePolicy,
    TokenBucket,
)
from repro.runtime.cache import (
    MISS,
    CachedSearcher,
    LUTCache,
    TTLLRUCache,
    fingerprint,
)
from repro.runtime.maintenance import MaintenanceScheduler
from repro.runtime.profile import PROFILES, RuntimeProfile
from repro.runtime.telemetry import RequestTrace, Telemetry

__all__ = [
    "profile",
    "RuntimeProfile",
    "PROFILES",
    "TTLLRUCache",
    "LUTCache",
    "CachedSearcher",
    "MISS",
    "fingerprint",
    "AdmissionController",
    "DegradePolicy",
    "TokenBucket",
    "Decision",
    "ADMIT",
    "DEGRADE",
    "SHED",
    "MaintenanceScheduler",
    "Telemetry",
    "RequestTrace",
]
