"""Background maintenance: compaction and drift-triggered recalibration
off the request path.

``MutableIndex.compact()`` blocks its caller for the whole merge build —
in a serving loop that cost lands on request latency.  The
``MaintenanceScheduler`` moves it to a daemon thread using the stream
layer's three-phase protocol (DESIGN.md §12):

    1. ``index.compact_snapshot()``   freeze the group under the write
                                      lock (copy-only), release the lock
    2. (off-lock)                     build the merged segment — the
                                      expensive inner-index build +
                                      possible Eq. 1 re-fit — while the
                                      request path keeps serving
    3. ``index.apply_compaction()``   atomic manifest swap under the
                                      lock; concurrent deletes re-applied,
                                      competing swaps detected and dropped

After a successful swap the scheduler also owns the *rerank-store
refresh*: the swap invalidated the stream index's cached merge re-score
store, so ``index.refresh_rerank_store()`` rebuilds it eagerly inside
the same background round (counted as ``rerank_refreshes``) instead of
letting the next query's plan pay for it.

Triggers, checked every ``interval_s``:

  * **structural** — the compactor's own ``should_compact`` (too many
    segments), running the policy's group pick;
  * **drift** — ``stats()["max_drift"]`` beyond the compaction policy's
    ``drift_threshold``: a *full* snapshot-compaction with
    recalibration, repairing the §3.2 data-driven constants the insert
    stream has left behind;

  * **tune** (lowest priority, only with a ``retune_fn``) — a loaded
    index carried a TuneTable measured on a different backend
    (``repro.tune.table.pending_mismatch()``): re-measure on *this*
    backend off the request path, install the fresh table, clear the
    pending one.  Counted as ``maintenance_retunes``; a failing re-tune
    counts ``maintenance_errors`` and leaves dispatch on its current
    (fallback or previously-adopted) configs.

The exact-parity invariant survives the background path: a full
snapshot-compaction with no concurrent writes swaps in a segment
bit-identical to a from-scratch build on ``live_items()``
(tests/test_runtime.py re-asserts it through these hooks).

``run_once`` is the synchronous entry (tests, serve's drain step);
``start``/``stop`` manage the thread.  All outcomes are counted into the
shared telemetry counters and logged as ``maintenance/*`` spans.
"""

from __future__ import annotations

import threading
from typing import Optional


class MaintenanceScheduler:
    """Drives background compaction/recalibration for one mutable index."""

    def __init__(
        self,
        index,
        *,
        interval_s: float = 0.25,
        drift_threshold: Optional[float] = None,
        telemetry=None,
        retune_fn=None,
    ):
        if not hasattr(index, "compact_snapshot"):
            raise TypeError(
                f"maintenance needs a mutable (stream) index, got "
                f"{getattr(index, 'kind', type(index).__name__)!r}"
            )
        self.index = index
        self.interval_s = float(interval_s)
        # None -> the index's own compaction policy threshold
        self.drift_threshold = (
            float(drift_threshold) if drift_threshold is not None
            else float(index.policy.drift_threshold)
        )
        self.telemetry = telemetry
        # zero-arg callable returning a fresh TuneTable for this backend
        # (e.g. lambda: repro.tune.autotune(smoke=True)); None disables
        # the re-tune trigger
        self.retune_fn = retune_fn
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        import collections

        self.counters = (telemetry.counters if telemetry is not None
                         else collections.Counter())

    # -- triggers ----------------------------------------------------------
    def _trigger(self) -> Optional[str]:
        idx = self.index
        if idx.compactor.should_compact(idx.manifest.segments):
            return "segments"
        st = idx.stats()
        if (st["segments"] > 0 and self.drift_threshold > 0
                and st["max_drift"] > self.drift_threshold):
            return "drift"
        if self.retune_fn is not None:
            from repro.tune import table as tunetable

            if tunetable.pending_mismatch() is not None:
                return "tune"
        return None

    # -- low-priority re-tune (saved-index table from a foreign backend) ---
    def _run_retune(self, out: dict) -> None:
        from repro.tune import table as tunetable

        pending = tunetable.pending_mismatch()
        out["pending_hash"] = (pending.table_hash() if pending is not None
                               else None)
        fresh = self.retune_fn()
        if fresh is not None:
            tunetable.install(fresh)
            out["table_hash"] = fresh.table_hash()
            out["swapped"] = True
        tunetable.clear_pending()

    # -- one maintenance round --------------------------------------------
    def run_once(self, force_full: bool = False) -> dict:
        """Check triggers; if one fires, snapshot-compact and swap.

        Returns an outcome record (also appended to telemetry):
        ``{"ran": bool, "trigger": ..., "swapped": bool, ...}``.
        """
        trigger = "forced" if force_full else self._trigger()
        if trigger is None:
            return {"ran": False}
        if trigger == "tune":
            out = {"ran": True, "trigger": "tune", "swapped": False}
            if self.telemetry is not None:
                with self.telemetry.span("maintenance/retune"):
                    self._run_retune(out)
            else:
                self._run_retune(out)
            self.counters["maintenance_rounds"] += 1
            self.counters["maintenance_retunes"] += 1
            if self.telemetry is not None:
                self.telemetry.event("maintenance", **out)
            return out
        full = force_full or trigger == "drift"
        out = {"ran": True, "trigger": trigger, "full": full, "swapped": False}

        def round_():
            pending = self.index.compact_snapshot(full=full)
            if pending is None:
                out["empty"] = True
                return
            out["swapped"] = bool(self.index.apply_compaction(pending))
            out["recalibrated"] = pending.recalibrated
            out["epoch"] = self.index.epoch
            if out["swapped"]:
                # the swap invalidated the merge re-score store; rebuild
                # it here so the cost lands in this background round, not
                # in the next query's plan
                out["rerank_refreshed"] = bool(
                    self.index.refresh_rerank_store())

        if self.telemetry is not None:
            with self.telemetry.span("maintenance/compact", trigger=trigger):
                round_()
        else:
            round_()
        self.counters["maintenance_rounds"] += 1
        if out["swapped"]:
            self.counters["maintenance_swaps"] += 1
            if out.get("rerank_refreshed"):
                self.counters["rerank_refreshes"] += 1
        elif not out.get("empty"):
            self.counters["maintenance_conflicts"] += 1
        if self.telemetry is not None:
            self.telemetry.event("maintenance", **out)
        return out

    # -- thread lifecycle --------------------------------------------------
    def start(self) -> "MaintenanceScheduler":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._loop, name="repro-maintenance", daemon=True
        )
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.run_once()
            except Exception as e:  # noqa: BLE001 — never kill the server
                self.counters["maintenance_errors"] += 1
                if self.telemetry is not None:
                    self.telemetry.event("maintenance_error", error=repr(e))

    def stop(self, timeout: float = 10.0) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout)
        self._thread = None

    def __enter__(self) -> "MaintenanceScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
