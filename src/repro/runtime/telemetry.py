"""Structured serve telemetry: per-request spans, a shared counter
registry, and the JSON event log the bench trend gate consumes.

One ``Telemetry`` object per serving session.  Three surfaces:

  * **counters** — a plain ``Counter`` shared *by reference* with the
    cache tiers and the admission controller, so every subsystem
    increments into one registry and the final report is one dict, not
    a reconciliation exercise.
  * **request traces** — ``telemetry.request(id)`` yields a
    ``RequestTrace``; phases (``queue_wait`` / ``pad`` / ``execute`` /
    ``rerank`` / ``merge`` / ...) are timed with ``trace.span(name)``
    or recorded directly with ``trace.phase(name, seconds)`` (for
    durations measured elsewhere, e.g. queue wait), annotations carry
    the engine stats; ``finish`` appends one event row.
  * **ad-hoc spans** — ``telemetry.span("maintenance/compact")`` times
    off-request work (the background compactor) into the same log.

``to_json`` writes ``{meta, counters, summary, events}`` where ``meta``
embeds the runtime-profile stamp — the artifact CI uploads next to the
``BENCH_*.json`` files, carrying the same provenance.
"""

from __future__ import annotations

import collections
import contextlib
import json
import time
from typing import Any, Callable, Optional

import numpy as np


class RequestTrace:
    """Span accumulator for one request; append-only until ``finish``."""

    def __init__(self, req_id, telemetry: "Telemetry"):
        self.req_id = req_id
        self._t = telemetry
        self.phases: dict[str, float] = {}
        self.fields: dict[str, Any] = {}
        self._done = False

    @contextlib.contextmanager
    def span(self, name: str):
        t0 = self._t.clock()
        try:
            yield self
        finally:
            self.phase(name, self._t.clock() - t0)

    def phase(self, name: str, seconds: float) -> None:
        self.phases[name] = self.phases.get(name, 0.0) + float(seconds)

    def annotate(self, **fields) -> None:
        self.fields.update(fields)

    def finish(self) -> dict:
        if not self._done:                      # idempotent
            self._done = True
            self._t._finish_request(self)
        return {"type": "request", "id": self.req_id,
                **{f"{k}_s": v for k, v in self.phases.items()},
                **self.fields}


class Telemetry:
    """The session-wide event log + counter registry."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 meta: Optional[dict] = None):
        self.clock = clock
        self.meta = dict(meta or {})
        self.counters: collections.Counter = collections.Counter()
        self.events: list[dict] = []
        self._phase_samples: dict[str, list[float]] = collections.defaultdict(list)

    # -- request path ------------------------------------------------------
    def request(self, req_id) -> RequestTrace:
        return RequestTrace(req_id, self)

    def _finish_request(self, trace: RequestTrace) -> None:
        self.counters["requests"] += 1
        for name, dur in trace.phases.items():
            self._phase_samples[name].append(dur)
        self.events.append({"type": "request", "id": trace.req_id,
                            **{f"{k}_s": v for k, v in trace.phases.items()},
                            **trace.fields})

    # -- ad-hoc (maintenance path) -----------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **fields):
        t0 = self.clock()
        row = {"type": "span", "name": name, **fields}
        try:
            yield row
        finally:
            row["dur_s"] = self.clock() - t0
            self._phase_samples[name].append(row["dur_s"])
            self.events.append(row)

    def event(self, type_: str, **fields) -> None:
        self.events.append({"type": type_, **fields})

    # -- rollups -----------------------------------------------------------
    def percentiles(self, name: str, qs=(50, 95, 99)) -> dict[str, float]:
        xs = self._phase_samples.get(name)
        if not xs:
            return {}
        return {f"p{q}_ms": float(np.percentile(xs, q)) * 1e3 for q in qs}

    def summary(self) -> dict:
        return {
            name: {"count": len(xs), "total_s": float(np.sum(xs)),
                   **self.percentiles(name)}
            for name, xs in sorted(self._phase_samples.items())
        }

    def to_json(self, path) -> dict:
        """Serialize ``{meta, counters, summary, events}``; returns the
        payload (path may be a filesystem path or a file-like object)."""
        payload = {
            "meta": self.meta,
            "counters": dict(self.counters),
            "summary": self.summary(),
            "events": self.events,
        }
        text = json.dumps(payload, indent=2, sort_keys=True, default=_scalar)
        if hasattr(path, "write"):
            path.write(text)
        else:
            with open(path, "w") as f:
                f.write(text)
        return payload


def _scalar(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, np.ndarray):
        return x.tolist()
    return str(x)
