"""Quickstart: the paper in 40 lines.

Learn Eq. 1 constants on a narrow-band corpus, quantize to int8, run an
exact MIP search in the integer domain, and compare recall + memory
against fp32 — the paper's core claim end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

from repro.core import learn_params, quantize, knn_recall
from repro.data import synthetic
from repro.knn import make_index

# 1. a corpus with the paper's Fig-1 value profile (50k x 256, values
#    exclusively inside (-.125, .125))
corpus, queries, metric = synthetic.load("product", n=50_000, n_queries=256)
print(f"corpus {corpus.shape}, metric={metric}, "
      f"values in [{float(corpus.min()):.4f}, {float(corpus.max()):.4f}]")

# 2. fit the quantization family (Q, phi): per-dim Gaussian constants
params = learn_params(corpus, bits=8, scheme="gaussian", sigmas=3.0)
codes = quantize(corpus, params)
print(f"codes dtype={codes.dtype}, "
      f"memory {codes.nbytes/1e6:.1f} MB vs fp32 {corpus.nbytes/1e6:.1f} MB "
      f"({codes.nbytes/corpus.nbytes:.0%})")

# 3. exact search in both domains — factory strings through the registry
idx_fp = make_index("flat", corpus, metric=metric)
idx_q8 = make_index("flat,lpq8@gaussian:3", corpus, metric=metric)

k = 100
_scores, gt = idx_fp.search(queries, k)
_scores, ids = idx_q8.search(queries, k)

# 4. the paper's claim: distance-order preservation => tiny recall loss
rec = float(knn_recall(corpus, queries, params, metric, k=k))
print(f"recall@{k} int8 vs fp32 exact: {rec:.4f}  (paper: ~0.98)")
print(f"index memory: fp32 {idx_fp.memory_bytes()/1e6:.1f} MB -> "
      f"int8 {idx_q8.memory_bytes()/1e6:.1f} MB")

# 5. beyond the paper: B=4 bit-packed two codes per byte (8x vs fp32),
#    scored by the engine's unpack-in-kernel fused scan
idx_q4 = make_index("flat,lpq4@gaussian:3", corpus, metric=metric)
res4 = idx_q4.search(queries, k)
rec4 = sum(
    len(set(a.tolist()) & set(b.tolist())) for a, b in zip(gt, res4.ids)
) / (gt.shape[0] * k)
print(f"recall@{k} packed int4 vs fp32 exact: {rec4:.4f}, "
      f"memory {idx_q4.memory_bytes()/1e6:.1f} MB "
      f"(stats: {res4.stats})")
