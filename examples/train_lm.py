"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps with the production loop (AdamW + schedule, checkpointing, resume),
then serve a few tokens from it through the paper-quantized int8 KV cache
and verify next-token agreement with the fp32 cache.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lm_data
from repro.models import transformer as TF
from repro.quantized import qkv_cache as QC
from repro.train import OptConfig, TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    # ~100M params: 12L x d640 x ffn 2560, 50k vocab
    cfg = TF.LMConfig(
        name="lm100m", n_layers=12, d_model=640, n_heads=10, n_kv=5,
        head_dim=64, d_ff=2560, vocab=50_176, act="silu",
        dtype="float32", block_q=128, block_kv=128, remat=False,
    )
    print(f"params: {cfg.param_count()/1e6:.1f}M")

    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=3e-4, schedule="wsd", warmup_steps=20,
                        total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=50, log_every=20)
    data = lm_data.batch_iterator(args.batch, args.seq_len, cfg.vocab)

    loss_fn = partial(TF.lm_loss, cfg=cfg)
    params, _opt, history = train(
        lambda p, b: loss_fn(p, b), params, data, opt_cfg, tcfg
    )
    print("loss trajectory:", [round(h["loss"], 3) for h in history])

    # --- serve through the int8 KV cache (the paper extension) ----------
    prompt = lm_data.lm_batch(jax.random.PRNGKey(9), 2, 32, cfg.vocab)["tokens"]
    _logits, caches = TF.prefill(params, prompt, cfg)
    max_len = 48

    kc, vc = TF.make_cache(cfg, 2, max_len, dtype=jnp.float32)
    kc = TF.write_prefix(kc, caches[0])
    vc = TF.write_prefix(vc, caches[1])
    qcache = QC.quantize_cache(caches[0], caches[1], max_len=max_len)
    print(f"KV cache: fp32 {kc.nbytes + vc.nbytes} B -> "
          f"int8 {qcache.k_codes.nbytes + qcache.v_codes.nbytes} B")

    tok_fp = prompt[:, -1:]
    tok_q8 = prompt[:, -1:]
    agree = 0
    for step in range(8):
        cur = jnp.int32(32 + step)
        lg_fp, (kc, vc) = TF.decode_step(params, (kc, vc), tok_fp, cur, cfg)
        lg_q8, qcache = QC.decode_step_q8(params, qcache, tok_q8, cur, cfg)
        tok_fp = jnp.argmax(lg_fp, -1)[:, None]
        tok_q8 = jnp.argmax(lg_q8, -1)[:, None]
        agree += int((np.asarray(tok_fp) == np.asarray(tok_q8)).all())
    print(f"greedy decode agreement (int8 vs fp32 cache): {agree}/8 steps")


if __name__ == "__main__":
    main()
