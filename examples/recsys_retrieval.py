"""Recsys scenario: train a reduced DLRM on synthetic CTR batches, then
use its item-embedding table for quantized candidate retrieval — the
paper's MIP search as the retrieval stage of a recommender.

    PYTHONPATH=src python examples/recsys_retrieval.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.core.preserve import recall_at_k
from repro.data import recsys_data
from repro.models.recsys import embedding as E
from repro.models.recsys import models as RM
from repro.models.recsys import retrieval as RT
from repro.train import OptConfig, TrainConfig, train


def main():
    cfg = get("dlrm-mlperf").reduced_config()
    params = RM.init_params(jax.random.PRNGKey(0), cfg)

    data = recsys_data.batch_iterator(256, cfg.n_dense, cfg.vocab_sizes)
    params, _opt, history = train(
        lambda p, b: RM.bce_loss(p, b, cfg),
        params,
        data,
        OptConfig(lr=1e-3, warmup_steps=10, total_steps=100),
        TrainConfig(steps=100, log_every=25),
    )
    print("bce loss:", [round(h["loss"], 4) for h in history])

    # retrieval stage: score users against the (largest) item table
    table = params["tables"]["t3"]["table"]          # [2000, d]
    qt = E.QuantizedTable.from_dense(table)
    user_emb = jax.random.normal(jax.random.PRNGKey(4), (16, cfg.embed_dim)) * 0.1

    s_fp, ids_fp = RT.retrieve_fp32(user_emb, table, k=50)
    s_q8, ids_q8 = RT.retrieve_quantized(user_emb, qt.codes, qt.params, k=50,
                                         use_pallas=False)
    rec = float(recall_at_k(ids_fp, ids_q8))
    print(f"retrieval recall@50 (int8 vs fp32): {rec:.4f}")
    print(f"candidate table: fp32 {table.nbytes} B -> int8 {qt.memory_bytes()} B "
          f"({qt.memory_bytes()/table.nbytes:.0%})")


if __name__ == "__main__":
    main()
