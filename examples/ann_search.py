"""End-to-end ANN serving scenario through the unified index API: build
fp32 + int8 HNSW and IVF indexes from factory strings, sweep EFS (the
paper's Fig 2 axis) with one SearchParams knob, and demonstrate the
save/load round-trip — every index behind the same four calls
(make_index / search / memory_bytes / save).

    PYTHONPATH=src python examples/ann_search.py [--n 4000]
"""

import argparse
import os
import tempfile
import time

import jax

from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import SearchParams, load_index, make_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    corpus, queries, metric = synthetic.load("product", args.n, 64)
    queries = queries[:64]
    _s, gt = exact_topk(corpus, queries, args.k, metric)

    print("== HNSW (the paper's primary target) ==")
    arms = {
        arm: make_index(factory, corpus, metric=metric,
                        ef_construction=80, batch_size=256)
        for arm, factory in (("fp32", "hnsw8"),
                             ("int8", "hnsw8,lpq8@gaussian:3"))
    }
    for arm, idx in arms.items():
        print(f"  {arm}: build {idx.build_seconds:.1f}s, "
              f"memory {idx.memory_bytes()/1e6:.1f} MB")
    for efs in (40, 80, 160):
        sp = SearchParams(ef_search=efs)
        for arm, idx in arms.items():
            t0 = time.perf_counter()
            res = idx.search(queries, args.k, sp)
            jax.block_until_ready(res.ids)
            dt = time.perf_counter() - t0
            rec = float(recall_at_k(gt, res.ids))
            print(f"  efs={efs:4d} {arm}: qps={len(queries)/dt:7.1f} "
                  f"recall@{args.k}={rec:.4f}")

    print("== IVF (TPU-native cluster-prune index) ==")
    ivf = make_index("ivf32,lpq8@gaussian:3", corpus, metric=metric)
    for nprobe in (4, 8, 16):
        res = ivf.search(queries, args.k, SearchParams(nprobe=nprobe))
        rec = float(recall_at_k(gt, res.ids))
        print(f"  nprobe={nprobe:3d} int8: recall@{args.k}={rec:.4f}")

    print("== Searcher: plan once, serve mixed batches (DESIGN.md §9) ==")
    lpq4 = make_index("flat,lpq4", corpus, metric=metric)
    rer = make_index("flat,lpq4+r32", corpus, metric=metric)
    searcher = rer.searcher(args.k, batch_sizes=(1, 8, 64))
    for qn in (1, 7, 64):
        res = searcher(queries[:qn])
        print(f"  batch={qn:3d} -> bucket={res.stats['bucket']:3d} "
              f"padded={res.stats['padded_q']}")
    rec4 = float(recall_at_k(gt, lpq4.searcher(args.k)(queries).ids))
    rec_r = float(recall_at_k(gt, searcher(queries).ids))
    print(f"  traces={searcher.trace_counts}  recall lpq4={rec4:.4f} "
          f"-> lpq4+r32={rec_r:.4f} (quantized scan selects, fp32 orders)")

    print("== save / load round-trip ==")
    path = os.path.join(tempfile.mkdtemp(), "ivf.npz")
    ivf.save(path)
    restored = load_index(path)
    res_a = ivf.search(queries, args.k, SearchParams(nprobe=8))
    res_b = restored.search(queries, args.k, SearchParams(nprobe=8))
    same = bool((res_a.ids == res_b.ids).all())
    print(f"  {path}: kind={restored.kind}, identical results: {same}")


if __name__ == "__main__":
    main()
