"""End-to-end ANN serving scenario: build fp32 + int8 HNSW and IVF
indexes over a product corpus, sweep EFS (the paper's Fig 2 axis), and
serve a batched query stream measuring QPS and recall for every arm.

    PYTHONPATH=src python examples/ann_search.py [--n 4000]
"""

import argparse
import time

import jax

from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import HNSWIndex, IVFIndex


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    corpus, queries, metric = synthetic.load("product", args.n, 64)
    queries = queries[:64]
    _s, gt = exact_topk(corpus, queries, args.k, metric)

    print("== HNSW (the paper's primary target) ==")
    arms = {
        "fp32": HNSWIndex.build(corpus, m=8, ef_construction=80, metric=metric,
                                batch_size=256),
        "int8": HNSWIndex.build(corpus, m=8, ef_construction=80, metric=metric,
                                quantized=True, sigmas=3.0, batch_size=256),
    }
    for arm, idx in arms.items():
        print(f"  {arm}: build {idx.build_seconds:.1f}s, "
              f"memory {idx.memory_bytes()/1e6:.1f} MB")
    for efs in (40, 80, 160):
        for arm, idx in arms.items():
            t0 = time.perf_counter()
            _s, ids = idx.search(queries, args.k, ef_search=efs)
            jax.block_until_ready(ids)
            dt = time.perf_counter() - t0
            rec = float(recall_at_k(gt, ids))
            print(f"  efs={efs:4d} {arm}: qps={len(queries)/dt:7.1f} "
                  f"recall@{args.k}={rec:.4f}")

    print("== IVF (TPU-native cluster-prune index) ==")
    ivf = IVFIndex.build(corpus, nlist=32, metric=metric, quantized=True, sigmas=3.0)
    for nprobe in (4, 8, 16):
        _s, ids = ivf.search(queries, args.k, nprobe=nprobe)
        rec = float(recall_at_k(gt, ids))
        print(f"  nprobe={nprobe:3d} int8: recall@{args.k}={rec:.4f}")


if __name__ == "__main__":
    main()
