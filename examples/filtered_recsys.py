"""Category-filtered two-tower retrieval (DESIGN.md §16): a recsys
candidate generator that must only surface items from the categories a
request is allowed to see (storefront section, region licensing, user
opt-outs), served through a mutable ``stream(ivf64,lpq8)`` index.

The item tower's embeddings land in a quantized IVF index; each item
carries a category id in a plain metadata column.  A request turns its
allowed categories into a :class:`repro.filter.Filter` bitmap riding
``SearchParams`` — the engine ANDs it into the same id fence that drops
padding and tombstones, so the filtered query costs a mask, not a
rescan, and survives live catalog churn (upserts/deletes) unchanged.

    PYTHONPATH=src python examples/filtered_recsys.py
"""

import jax
import numpy as np

from repro.filter import Filter
from repro.knn import SearchParams, make_index

N_ITEMS, D, N_USERS, K, N_CATS = 3000, 32, 8, 10, 6


def towers(key):
    """A stand-in two-tower geometry: items on a latent sphere, each
    user tower output near a handful of items (their history)."""
    k1, k2, k3 = jax.random.split(key, 3)
    items = jax.random.normal(k1, (N_ITEMS, D))
    items = items / jax.numpy.linalg.norm(items, axis=1, keepdims=True)
    anchor = jax.random.randint(k2, (N_USERS,), 0, N_ITEMS)
    users = items[anchor] + 0.15 * jax.random.normal(k3, (N_USERS, D))
    return np.asarray(items), np.asarray(users)


def oracle(items, users, allowed_ids, k):
    """Brute-force filtered MIP top-k in fp32 (ids in catalog space)."""
    scores = users @ items[allowed_ids].T
    order = np.argsort(-scores, axis=1)[:, :k]
    return allowed_ids[order]


def main():
    items, users = towers(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    category = rng.integers(0, N_CATS, N_ITEMS)

    idx = make_index("stream(ivf64,lpq8)+r32", items, metric="ip",
                     key=jax.random.PRNGKey(1))
    print(f"[filtered_recsys] catalog: {idx.n} items x {D}d, "
          f"{N_CATS} categories, kind={idx.kind}")

    # one storefront section: categories {1, 4} only
    filt = Filter.from_column(category, {1, 4})
    sp = SearchParams(nprobe=64, filter=filt)
    res = idx.search(users, K, sp)
    ids = np.asarray(res.ids)
    assert np.isin(category[ids[ids >= 0]], [1, 4]).all()
    gt = oracle(items, users, np.where(filt.mask)[0], K)
    hit = np.mean([len(set(r) & set(g)) / K for r, g in zip(ids, gt)])
    print(f"[filtered_recsys] categories {{1,4}}: selectivity="
          f"{filt.selectivity:.3f} recall@{K} vs filtered oracle={hit:.3f} "
          f"(stats: filter_selectivity="
          f"{res.stats['filter_selectivity']})")

    # catalog churn: new items arrive in category 4, stale ones retire —
    # the same request-side bitmap (extended with the column) stays exact
    new_items = items[:64] * 0.9 + 0.1 * rng.standard_normal((64, D))
    new_ids = np.arange(N_ITEMS, N_ITEMS + 64)
    idx.upsert(new_ids, new_items)
    idx.delete(np.where(category == 1)[0][:50])
    category2 = np.concatenate([category, np.full(64, 4)])

    filt2 = Filter.from_column(category2, {1, 4})
    res2 = idx.search(users, K, SearchParams(nprobe=64, filter=filt2))
    ids2 = np.asarray(res2.ids)
    live = ids2[ids2 >= 0]
    assert np.isin(category2[live], [1, 4]).all()
    deleted = set(np.where(category == 1)[0][:50].tolist())
    assert not (set(live.tolist()) & deleted), "tombstoned item surfaced"
    print(f"[filtered_recsys] after churn (+64 upserts, -50 deletes): "
          f"n={idx.n} live={idx.stats()['live']} "
          f"new-item hits={int(np.isin(ids2, new_ids).sum())} "
          f"(filter ∧ tombstone composed in one bitmap)")


if __name__ == "__main__":
    main()
